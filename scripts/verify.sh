#!/usr/bin/env bash
# Tier-1 verification + docs link-check. Plain shell so any CI can call
# it:   bash scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== docs link-check: every repo path referenced in README.md and" \
     "docs/ARCHITECTURE.md must exist =="
missing=0
for doc in README.md docs/ARCHITECTURE.md; do
    # backtick-quoted repo paths: src/..., tests/..., examples/..., etc.
    for p in $(grep -o '`[A-Za-z0-9_./-]*`' "$doc" | tr -d '`' \
               | grep -E '^(src|tests|examples|benchmarks|docs|scripts)/' \
               | sed 's:/$::' | sort -u); do
        if [ ! -e "$p" ]; then
            echo "MISSING: $p (referenced in $doc)"
            missing=1
        fi
    done
    # top-level files referenced in docs
    for p in $(grep -o '`[A-Za-z0-9_.-]*\.\(md\|txt\|ini\|yml\)`' "$doc" \
               | tr -d '`' | sort -u); do
        case "$p" in
            manifest.yml|m.yml) continue ;;   # illustrative names
        esac
        if [ ! -e "$p" ]; then
            echo "MISSING: $p (referenced in $doc)"
            missing=1
        fi
    done
done
if [ "$missing" -ne 0 ]; then
    echo "docs link-check FAILED"
    exit 1
fi
echo "docs link-check OK"

echo "== exception hygiene: no swallowed exceptions (except ...: pass) =="
python - <<'EOF'
import pathlib
import re
import sys

# 'except:'/'except Exception:' followed by a bare 'pass' silently eats
# scheduler and learner bugs (PR 2 satellite); narrow except clauses
# (e.g. NoNodeError) stay allowed.
pat = re.compile(
    r"except(\s+(Exception|BaseException))?\s*(as\s+\w+\s*)?"
    r":\s*(\n\s*)?pass\b")
bad = []
for root in ("src", "benchmarks"):
    for p in sorted(pathlib.Path(root).rglob("*.py")):
        text = p.read_text()
        for m in pat.finditer(text):
            line = text[: m.start()].count("\n") + 1
            bad.append(f"{p}:{line}")
if bad:
    print("swallowed exceptions (except ...: pass) at:")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("except-pass check OK")
EOF

echo "== logging hygiene: no bare print() in src/ outside the CLI" \
     "(everything routes through the structured 'repro' logger) =="
python - <<'EOF'
import ast
import pathlib
import sys

# the CLI prints to stdout by contract; everything else must log so the
# job/trace context filter and the per-job log hub see it
ALLOW = {"src/repro/service/cli.py"}
bad = []
for p in sorted(pathlib.Path("src").rglob("*.py")):
    if p.as_posix() in ALLOW:
        continue
    tree = ast.parse(p.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Name) \
                and node.func.id == "print":
            bad.append(f"{p}:{node.lineno}")
if bad:
    print("bare print() outside the CLI (use logging.getLogger"
          "('repro.<area>')):")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("print-free check OK")
EOF

echo "== ps-dataplane benchmark smoke (compression none vs int8) =="
# tiny invocation of the data-plane bench: proves both wire formats
# train end-to-end; writes to a temp file so the committed
# BENCH_ps_dataplane.json (full 30-step run) is not clobbered
PS_DATAPLANE_STEPS=6 PS_DATAPLANE_OUT="$(mktemp /tmp/ps_dataplane.XXXXXX.json)" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/run.py ps-dataplane

echo "== serving smoke (deploy smoke arch, N predicts, drain; fails on" \
     "any rejected request at smoke load) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import tempfile
import time

import numpy as np

from repro.service.core import DLaaSCore

core = DLaaSCore(tempfile.mkdtemp(prefix="verify_serving_"),
                 tick_interval=0.005)
try:
    eid = core.deploy_endpoint(arch="stablelm-1.6b", capacity=2,
                               max_queue=16, max_new=4)["endpoint_id"]
    t0 = time.time()
    while core.endpoint_status(eid)["state"] != "READY":
        if time.time() - t0 > 300:
            raise SystemExit("serving smoke FAILED: endpoint not READY")
        time.sleep(0.1)
    rng = np.random.RandomState(0)
    for i in range(6):
        out = core.predict(eid, rng.randint(0, 100, size=8), max_new=4)
        assert len(out["tokens"]) == 4, out
    core.stop_endpoint(eid)
    t0 = time.time()
    while True:
        st = core.endpoint_status(eid)
        if st["state"] == "STOPPED":
            break
        if time.time() - t0 > 60:
            raise SystemExit("serving smoke FAILED: endpoint not STOPPED")
        time.sleep(0.1)
    stats = st["stats"]
    assert stats["rejected_total"] == 0, \
        f"serving smoke FAILED: rejected requests at smoke load: {stats}"
    assert stats["completed_total"] == 6, stats
    print("serving smoke OK:",
          {k: stats[k] for k in ("completed_total", "p50_latency_s",
                                 "mean_batch_occupancy")})
finally:
    core.close()
EOF

echo "== observability smoke: scrape /metrics during a training," \
     "validate Prometheus text + dlaas_ families, live follow streams," \
     "single-trace timeline =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import tempfile
import time
import urllib.request

from repro.observability.export import parse_prometheus_text
from repro.service.rest import DLaaSServer

MANIFEST = ("name: obs-smoke\nlearners: 2\ngpus: 1\nsteps: 60\n"
            "checkpoint_every: 20\nframework:\n  name: repro-mlp\n"
            "  d_in: 16\n  n_classes: 4\n")


def req(url, method="GET", body=None):
    data = json.dumps(body).encode() if body is not None else None
    r = urllib.request.Request(url, data=data, method=method)
    r.add_header("Authorization", "Bearer verify")
    if data:
        r.add_header("Content-Type", "application/json")
    with urllib.request.urlopen(r) as resp:
        return resp.read()


with DLaaSServer(tempfile.mkdtemp(prefix="verify_obs_")) as srv:
    base = srv.url
    mid = json.loads(req(f"{base}/v1/models", "POST",
                         {"manifest": MANIFEST}))["model_id"]
    tid = json.loads(req(f"{base}/v1/trainings", "POST",
                         {"model_id": mid}))["training_id"]
    # scrape DURING the run: wait for PROCESSING, then hit /metrics
    t0 = time.time()
    while True:
        st = json.loads(req(f"{base}/v1/trainings/{tid}"))["status"]
        if st == "PROCESSING":
            break
        if st in ("COMPLETED", "FAILED", "KILLED") \
                or time.time() - t0 > 300:
            raise SystemExit(f"obs smoke FAILED: never PROCESSING ({st})")
        time.sleep(0.02)
    # a Prometheus scraper negotiates on the exact Content-Type
    with urllib.request.urlopen(f"{base}/metrics") as resp:
        ctype = resp.headers.get("Content-Type")
        text = resp.read().decode()
    if ctype != "text/plain; version=0.0.4; charset=utf-8":
        raise SystemExit(f"obs smoke FAILED: /metrics Content-Type "
                         f"{ctype!r} is not the 0.0.4 exposition")
    parsed = parse_prometheus_text(text)       # raises on malformed text
    fams = parsed["families"]
    for want in ("dlaas_queue_depth", "dlaas_cluster_nodes",
                 "dlaas_cluster_gpus_free", "dlaas_journal_seq",
                 "dlaas_journal_compactions_total", "dlaas_trace_spans",
                 "dlaas_platform_events_total", "dlaas_slo_burn_rate",
                 "dlaas_slo_objective", "dlaas_alerts_active",
                 "dlaas_alerts_fired_total",
                 "dlaas_alerts_remediations_total"):
        if want not in fams:
            raise SystemExit(f"obs smoke FAILED: /metrics missing "
                             f"{want}; has {sorted(fams)}")
    # live streams while the job runs: loss records + structured logs
    raw = req(f"{base}/v1/trainings/{tid}/metrics?follow=1&max_s=3")
    mlines = [json.loads(l) for l in raw.splitlines() if l.strip()]
    if not (mlines and mlines[0]["type"] == "snapshot"
            and any(r.get("metric") == "loss" for r in mlines[1:])):
        raise SystemExit(f"obs smoke FAILED: metrics?follow=1 streamed "
                         f"no live loss records ({len(mlines)} lines)")
    raw = req(f"{base}/v1/trainings/{tid}/logs?follow=1&max_s=3")
    llines = [json.loads(l) for l in raw.splitlines() if l.strip()]
    if not any("step=" in r.get("line", "") for r in llines):
        raise SystemExit("obs smoke FAILED: logs?follow=1 streamed no "
                         f"training lines ({len(llines)} records)")
    t0 = time.time()
    while json.loads(req(f"{base}/v1/trainings/{tid}"))["status"] \
            != "COMPLETED":
        if time.time() - t0 > 300:
            raise SystemExit("obs smoke FAILED: training never finished")
        time.sleep(0.1)
    # one trace, phases tile the lifetime without overlap
    tl = json.loads(req(f"{base}/v1/trainings/{tid}/timeline"))
    names = [s["name"] for s in tl["spans"]]
    for want in ("job", "submit", "queue_wait", "place", "run",
                 "checkpoint_publish"):
        if want not in names:
            raise SystemExit(f"obs smoke FAILED: timeline missing "
                             f"{want!r} span: {names}")
    phases = sorted((s for s in tl["spans"]
                     if s["name"] in ("queue_wait", "place", "run",
                                      "preempted")),
                    key=lambda s: s["start"])
    for a, b in zip(phases, phases[1:]):
        if a["end"] is None or a["end"] > b["start"] + 1e-9:
            raise SystemExit(f"obs smoke FAILED: overlapping phases "
                             f"{a['name']}->{b['name']}")
    print(f"observability smoke OK: {len(fams)} families, "
          f"{len(mlines)} live metric lines, {len(llines)} live log "
          f"records, {len(tl['spans'])} spans in one trace")
EOF

echo "== perf regression gate: fresh trajectory benches vs committed" \
     "BENCH_*.json (tolerance GATE_TOLERANCE, default 0.5) =="
# re-runs the backends/ps-dataplane/serving benches into a temp dir and
# requires every rate metric to reach GATE_TOLERANCE x its committed
# baseline; exit 1 on regression. The band is wide on purpose (container
# speed varies several-fold) — override with e.g. GATE_TOLERANCE=0.25
# on very slow CI hosts, or GATE_BENCHES=ps_dataplane to subset.
GATE_TOLERANCE="${GATE_TOLERANCE:-0.5}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/run.py gate

echo "== backend-parity + manifest test groups =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_backends.py tests/test_manifest.py

echo "== chaos drill: seeded kill/drain replay + 2-node node-kill for" \
     "both backends + serving-node kill (zero lost requests) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import tempfile
import time

from repro.platform.cluster import (Cluster, Node, Resources, RUNNING,
                                    Scheduler)
from repro.platform.faults import (DRAIN, FaultEvent, FaultInjector,
                                   FaultSchedule, KILL)
from repro.service.core import DLaaSCore


def wait_until(cond, timeout=300.0, desc="condition"):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if cond():
            return
        time.sleep(0.02)
    raise SystemExit(f"chaos drill FAILED: timed out waiting for {desc}")


# -- determinism: the same seed must replay the identical transition log
def drill(seed):
    c = Cluster([Node(f"n{i}", Resources(cpus=8, gpus=2, memory_mb=16000))
                 for i in range(2)])
    s = Scheduler(c)
    s.faults = FaultInjector(FaultSchedule.seeded(
        seed, sorted(c.nodes), n_events=4, horizon=10,
        kinds=(KILL, DRAIN)))
    for _ in range(12):
        s.tick()
    assert s.faults.done()
    return list(c.transitions)


log = drill(29)
assert log and log == drill(29), \
    "chaos drill FAILED: seeded drill did not replay tick-exact"
print(f"replay OK: {len(log)} transitions, identical across two runs")

PS_MANIFEST = ("name: chaos-ps\nlearners: 2\ngpus: 1\nsteps: 40\n"
               "checkpoint_every: 5\nframework:\n  name: repro-mlp\n"
               "  d_in: 16\n  n_classes: 4\n")
PJIT_MANIFEST = ("name: chaos-pjit\nlearners: 1\ngpus: 2\nsteps: 40\n"
                 "batch_docs: 2\ncheckpoint_every: 10\n"
                 "data:\n  n_docs: 32\n  seq_len: 16\n"
                 "framework:\n  name: repro-lm\n  arch: stablelm-1.6b\n"
                 "  distribution: pjit\n")


# -- both backends: kill the busy node mid-run; the job must resume
# from its checkpoint on the surviving node and complete
def backend_drill(dist):
    c = Cluster([Node(f"c{i}", Resources(cpus=16, gpus=2,
                                         memory_mb=64000))
                 for i in range(2)])
    core = DLaaSCore(tempfile.mkdtemp(prefix=f"verify_chaos_{dist}_"),
                     tick_interval=0.005, cluster=c)
    try:
        man = PJIT_MANIFEST if dist == "pjit" else PS_MANIFEST
        mid = core.deploy_model(man)["model_id"]
        tid = core.create_training(mid)["training_id"]
        wait_until(lambda: core.training_status(tid)["steps_done"] >= 10
                   and core.metrics.checkpoints(tid),
                   desc=f"{dist}: 10 steps + a checkpoint")
        core.pause_training(tid)      # gate at a step boundary
        gid = f"{tid}-workers" if dist == "pjit" else f"{tid}-learners"
        app = core.scheduler.apps[gid]
        victim = [t.node for t in app.tasks.values()
                  if t.state == RUNNING and t.node][0]
        core.inject_faults(events=[
            FaultEvent(KILL, victim, at_tick=core.cluster.clock + 1)])
        wait_until(lambda: core.scheduler.faults.done(),
                   desc=f"{dist}: fault fired")
        wait_until(lambda: any("resumed from checkpoint" in l
                               for l in core.training_logs(tid)),
                   desc=f"{dist}: checkpoint resume on survivor")
        core.resume_training(tid)
        if core.wait_for(tid, timeout=300) != "COMPLETED":
            raise SystemExit(f"chaos drill FAILED: {dist} job did not "
                             f"complete after node kill")
        st = core.training_status(tid)
        assert st["steps_done"] >= 40, st
        assert not core.cluster.nodes[victim].alive
        print(f"{dist} drill OK: killed {victim}, resumed from "
              f"checkpoint, {st['steps_done']} steps done")
    finally:
        core.close()


backend_drill("software-ps")
backend_drill("pjit")


# -- serving: kill the endpoint's node with requests queued; the engine
# must re-queue them and answer every one after re-placement
def serving_drill():
    c = Cluster([Node(f"s{i}", Resources(cpus=8, gpus=1,
                                         memory_mb=16000))
                 for i in range(2)])
    core = DLaaSCore(tempfile.mkdtemp(prefix="verify_chaos_srv_"),
                     tick_interval=0.005, cluster=c)
    try:
        eid = core.deploy_endpoint(arch="stablelm-1.6b", capacity=2,
                                   max_new=2)["endpoint_id"]
        wait_until(lambda: core.endpoint_status(eid)["state"] == "READY",
                   desc="endpoint READY")
        core.predict(eid, [1, 2, 3], max_new=2)        # warm the jits
        core.pause_training(eid)      # hold the serve loop
        eng = core.endpoints[eid].engine
        reqs = [eng.submit([4, 5, 6], max_new=2),
                eng.submit([7, 8], max_new=2)]
        app = core.scheduler.apps[f"{eid}-servers"]
        victim = [t.node for t in app.tasks.values()
                  if t.state == RUNNING][0]
        core.inject_faults(events=[
            FaultEvent(KILL, victim, at_tick=core.cluster.clock + 1)])
        wait_until(lambda: any(t.state == RUNNING and t.node != victim
                               for t in app.tasks.values()),
                   desc="endpoint re-placed on survivor")
        core.resume_training(eid)
        for r in reqs:
            if not r.wait(180) or r.status != "DONE":
                raise SystemExit("chaos drill FAILED: lost request "
                                 f"{r.req_id}: {r.status}")
        wait_until(lambda: core.endpoint_status(eid)["state"] == "READY",
                   desc="endpoint READY after kill")
        core.stop_endpoint(eid)
        print(f"serving drill OK: killed {victim}, zero lost requests")
    finally:
        core.close()


serving_drill()
print("chaos drill OK")
EOF

echo "== health drill: seeded straggler -> burn/anomaly alert ->" \
     "auto-restart remediation -> completion with loss parity," \
     "deterministic across two runs =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import tempfile
import time
import urllib.request

from repro.platform.faults import FaultSchedule
from repro.service.rest import DLaaSServer

MANIFEST = ("name: health-drill\nlearners: 2\ngpus: 1\nsteps: 40\n"
            "checkpoint_every: 5\nlr: 0.3\nframework:\n"
            "  name: repro-mlp\n  d_in: 16\n  n_classes: 4\n"
            "  distribution: software-ps\n")
SEED = 11


def req(url):
    r = urllib.request.Request(url)
    r.add_header("Authorization", "Bearer verify")
    with urllib.request.urlopen(r) as resp:
        return json.loads(resp.read())


def run(inject):
    """One training; returns (final_loss, straggler alert sequence,
    remediation log, HTTP /v1/alerts report, timeline span names)."""
    with DLaaSServer(tempfile.mkdtemp(prefix="verify_health_"),
                     tick_interval=0.005, durable=False) as srv:
        core = srv.core
        core.health.cooldown_s = 1.0
        mid = core.deploy_model(MANIFEST)["model_id"]
        tid = core.create_training(mid)["training_id"]
        if inject:
            sched = FaultSchedule.seeded_straggler(
                SEED, tid, 2, at_step=3, seconds=0.08)
            core.inject_faults(events=sched.events)
            t0 = time.time()
            while not any(
                    r["action"] == "restart_learner"
                    for r in core.health.alerts.remediations()):
                if time.time() - t0 > 300:
                    raise SystemExit("health drill FAILED: straggler "
                                     "remediation never ran")
                time.sleep(0.02)
        if core.wait_for(tid, timeout=300) != "COMPLETED":
            raise SystemExit(f"health drill FAILED: job did not "
                             f"complete ({core.lcm.job_state(tid)})")
        loss = core.metrics.series(tid, "loss").values[-1]
        rep = req(f"{srv.url}/v1/alerts")
        fired = rep["history"] + rep["active"]
        # the deterministic slice: seeded straggler alerts + what the
        # controller did about them (throughput/latency SLO alerts are
        # timing-dependent and excluded on purpose)
        alerts, seen = [], set()
        for a in sorted(fired, key=lambda a: a["seq"]):
            k = (a["name"], a["scope"])
            if a["name"] == "straggler" and k not in seen:
                seen.add(k)
                alerts.append(k)
        rems, seen = [], set()
        for r in rep["remediations"]:
            k = (r["action"], r["scope"], r.get("task", ""))
            if r["action"] == "restart_learner" and k not in seen:
                seen.add(k)
                rems.append(k)
        names = [s["name"]
                 for s in core.training_timeline(tid)["spans"]]
        return loss, alerts, rems, rep, names, tid


base_loss, _, _, _, _, _ = run(inject=False)
loss1, alerts1, rems1, rep1, names1, tid = run(inject=True)
loss2, alerts2, rems2, _, _, _ = run(inject=True)

victim = FaultSchedule.seeded_straggler(SEED, tid, 2).events[0].member
scope = f"{tid}/learner-{victim}"
if alerts1 != [("straggler", scope)]:
    raise SystemExit(f"health drill FAILED: expected one straggler "
                     f"alert on {scope}, got {alerts1}")
if rems1 != [("restart_learner", scope,
              f"{tid}-learners.{victim}")]:
    raise SystemExit(f"health drill FAILED: remediation log "
                     f"{rems1} did not requeue the victim learner")
if (alerts1, rems1) != (alerts2, rems2):
    raise SystemExit(f"health drill FAILED: seeded drill not "
                     f"deterministic: {(alerts1, rems1)} vs "
                     f"{(alerts2, rems2)}")
# the alert reached BOTH surfaces: /v1/alerts and the job timeline
if not any(a["name"] == "straggler" and a["scope"] == scope
           for a in rep1["history"] + rep1["active"]):
    raise SystemExit("health drill FAILED: straggler missing from "
                     "/v1/alerts")
for want in ("alert", "remediation"):
    if want not in names1:
        raise SystemExit(f"health drill FAILED: no {want!r} event in "
                         f"the job timeline: {sorted(set(names1))}")
# loss parity: the remediated run converges like the unfaulted one
if loss1 > max(2 * base_loss, base_loss + 0.3):
    raise SystemExit(f"health drill FAILED: loss {loss1:.4f} vs "
                     f"unfaulted baseline {base_loss:.4f}")
print(f"health drill OK: straggler {scope} alerted + requeued, "
      f"deterministic across two seeded runs, loss {loss1:.4f} vs "
      f"baseline {base_loss:.4f}")
EOF

echo "== storage hygiene: production object-store I/O must go through" \
     "StorageManager's with_backoff wrappers, never raw Store methods =="
python - <<'EOF'
import pathlib
import re
import sys

# direct store calls skip the exponential-backoff retry the paper
# requires for Object Store access; everything in src/ must route
# through StorageManager.download/upload (platform/storage.py)
pat = re.compile(
    r"(\bget_store\s*\(|\bstore\.(put|get|list|delete|exists)\s*\(|"
    r"\.stores\[)")
bad = []
for p in sorted(pathlib.Path("src").rglob("*.py")):
    if p.as_posix() == "src/repro/platform/storage.py":
        continue
    text = p.read_text()
    for m in pat.finditer(text):
        line = text[: m.start()].count("\n") + 1
        bad.append(f"{p}:{line}: {m.group(0)}")
if bad:
    print("raw object-store access outside the backoff wrapper:")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("storage backoff-path check OK")
EOF

echo "== crash-recovery drill: hard-kill (SIGKILL) a core subprocess" \
     "mid-training, recover a fresh core on the same workdir =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import os
import pathlib
import signal
import subprocess
import sys
import tempfile
import time

WORKDIR = tempfile.mkdtemp(prefix="verify_crash_")
MARKER = pathlib.Path(WORKDIR) / "marker.json"

# -- phase 1: a real OS process builds state, then is SIGKILLed --------
CHILD = r'''
import json, pathlib, sys, time
from repro.service.core import DLaaSCore

workdir = sys.argv[1]
MANIFEST = ("name: crash-drill\nlearners: 1\ngpus: 1\nsteps: 2000\n"
            "checkpoint_every: 100\nframework:\n  name: repro-mlp\n"
            "  d_in: 16\n  n_classes: 4\n")
core = DLaaSCore(workdir, tick_interval=0.005)
eid = core.deploy_endpoint(arch="stablelm-1.6b", max_new=2,
                           idempotency_key="drill-ep")["endpoint_id"]
t0 = time.time()
while core.endpoint_status(eid)["state"] != "READY":
    if time.time() - t0 > 300:
        raise SystemExit("child: endpoint never READY")
    time.sleep(0.1)
pre = core.predict(eid, [1, 2, 3], max_new=2)["tokens"]
mid = core.deploy_model(MANIFEST)["model_id"]
tid = core.create_training(mid, user="alice",
                           idempotency_key="drill-sub")["training_id"]
t0 = time.time()
while not core.metrics.checkpoints(tid):
    if time.time() - t0 > 300:
        raise SystemExit("child: no checkpoint landed")
    time.sleep(0.05)
core.pause_training(tid)     # hold mid-flight so the kill is mid-job
pathlib.Path(workdir, "marker.json").write_text(json.dumps(
    {"tid": tid, "eid": eid, "mid": mid, "pre_tokens": pre}))
time.sleep(600)              # parent SIGKILLs us here
'''
child = subprocess.Popen([sys.executable, "-c", CHILD, WORKDIR])
t0 = time.time()
while not MARKER.exists():
    if child.poll() is not None:
        raise SystemExit("crash drill FAILED: child died before marker "
                         f"(rc={child.returncode})")
    if time.time() - t0 > 600:
        child.kill()
        raise SystemExit("crash drill FAILED: child never wrote marker")
    time.sleep(0.1)
ids = json.loads(MARKER.read_text())
os.kill(child.pid, signal.SIGKILL)       # no shutdown hook runs
child.wait()

# -- phase 2: fresh core, same workdir — replay + recover --------------
from repro.service.core import DLaaSCore

core = DLaaSCore(WORKDIR, tick_interval=0.005)
try:
    rep = core.recovery_report()
    tid, eid = ids["tid"], ids["eid"]
    assert rep["recovered"], rep
    if tid not in rep["trainings"]["resumed"] + rep["trainings"]["requeued"]:
        raise SystemExit(f"crash drill FAILED: {tid} not relaunched: {rep}")
    if eid not in rep["endpoints"]["redeployed"]:
        raise SystemExit(f"crash drill FAILED: {eid} not redeployed: {rep}")
    # replayed Idempotency-Key returns the ORIGINAL job, no duplicate
    again = core.create_training(ids["mid"], user="alice",
                                 idempotency_key="drill-sub")
    assert again["training_id"] == tid, again
    if core.wait_for(tid, timeout=600) != "COMPLETED":
        raise SystemExit("crash drill FAILED: training did not complete "
                         f"after recovery: {core.lcm.job_state(tid)}")
    t0 = time.time()
    while core.endpoint_status(eid)["state"] != "READY":
        if time.time() - t0 > 300:
            raise SystemExit("crash drill FAILED: endpoint not READY "
                             "after recovery")
        time.sleep(0.1)
    post = core.predict(eid, [1, 2, 3], max_new=2)["tokens"]
    assert post == ids["pre_tokens"], (post, ids["pre_tokens"])
    # the recovered job's timeline continues the submission-time trace
    # and records the recovery pass as an event
    tl = core.training_timeline(tid)
    names = [s["name"] for s in tl["spans"]]
    if "recovery" not in names:
        raise SystemExit(f"crash drill FAILED: no recovery event in the "
                         f"recovered timeline: {names}")
    rec = core._zget(f"/dlaas/jobs/{tid}/record") or {}
    if rec.get("trace_id") and tl["trace_id"] != rec["trace_id"]:
        raise SystemExit(f"crash drill FAILED: timeline trace "
                         f"{tl['trace_id']} != persisted "
                         f"{rec['trace_id']}")
    print(f"crash-recovery drill OK: journal {rep['journal']}, "
          f"{tid} completed after SIGKILL, {eid} serving again, "
          f"idempotent replay returned the original ids, recovery "
          f"event in the persisted trace {tl['trace_id']}")
finally:
    core.close()
EOF

echo "== tier-1 tests (-rs: every skip must name its reason) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -rs "$@"
