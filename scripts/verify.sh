#!/usr/bin/env bash
# Tier-1 verification + docs link-check. Plain shell so any CI can call
# it:   bash scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== docs link-check: every repo path referenced in README.md and" \
     "docs/ARCHITECTURE.md must exist =="
missing=0
for doc in README.md docs/ARCHITECTURE.md; do
    # backtick-quoted repo paths: src/..., tests/..., examples/..., etc.
    for p in $(grep -o '`[A-Za-z0-9_./-]*`' "$doc" | tr -d '`' \
               | grep -E '^(src|tests|examples|benchmarks|docs|scripts)/' \
               | sed 's:/$::' | sort -u); do
        if [ ! -e "$p" ]; then
            echo "MISSING: $p (referenced in $doc)"
            missing=1
        fi
    done
    # top-level files referenced in docs
    for p in $(grep -o '`[A-Za-z0-9_.-]*\.\(md\|txt\|ini\|yml\)`' "$doc" \
               | tr -d '`' | sort -u); do
        case "$p" in
            manifest.yml|m.yml) continue ;;   # illustrative names
        esac
        if [ ! -e "$p" ]; then
            echo "MISSING: $p (referenced in $doc)"
            missing=1
        fi
    done
done
if [ "$missing" -ne 0 ]; then
    echo "docs link-check FAILED"
    exit 1
fi
echo "docs link-check OK"

echo "== exception hygiene: no swallowed exceptions (except ...: pass) =="
python - <<'EOF'
import pathlib
import re
import sys

# 'except:'/'except Exception:' followed by a bare 'pass' silently eats
# scheduler and learner bugs (PR 2 satellite); narrow except clauses
# (e.g. NoNodeError) stay allowed.
pat = re.compile(
    r"except(\s+(Exception|BaseException))?\s*(as\s+\w+\s*)?"
    r":\s*(\n\s*)?pass\b")
bad = []
for root in ("src", "benchmarks"):
    for p in sorted(pathlib.Path(root).rglob("*.py")):
        text = p.read_text()
        for m in pat.finditer(text):
            line = text[: m.start()].count("\n") + 1
            bad.append(f"{p}:{line}")
if bad:
    print("swallowed exceptions (except ...: pass) at:")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("except-pass check OK")
EOF

echo "== ps-dataplane benchmark smoke (compression none vs int8) =="
# tiny invocation of the data-plane bench: proves both wire formats
# train end-to-end; writes to a temp file so the committed
# BENCH_ps_dataplane.json (full 30-step run) is not clobbered
PS_DATAPLANE_STEPS=6 PS_DATAPLANE_OUT="$(mktemp /tmp/ps_dataplane.XXXXXX.json)" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/run.py ps-dataplane

echo "== serving smoke (deploy smoke arch, N predicts, drain; fails on" \
     "any rejected request at smoke load) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import tempfile
import time

import numpy as np

from repro.service.core import DLaaSCore

core = DLaaSCore(tempfile.mkdtemp(prefix="verify_serving_"),
                 tick_interval=0.005)
try:
    eid = core.deploy_endpoint(arch="stablelm-1.6b", capacity=2,
                               max_queue=16, max_new=4)["endpoint_id"]
    t0 = time.time()
    while core.endpoint_status(eid)["state"] != "READY":
        if time.time() - t0 > 300:
            raise SystemExit("serving smoke FAILED: endpoint not READY")
        time.sleep(0.1)
    rng = np.random.RandomState(0)
    for i in range(6):
        out = core.predict(eid, rng.randint(0, 100, size=8), max_new=4)
        assert len(out["tokens"]) == 4, out
    core.stop_endpoint(eid)
    t0 = time.time()
    while True:
        st = core.endpoint_status(eid)
        if st["state"] == "STOPPED":
            break
        if time.time() - t0 > 60:
            raise SystemExit("serving smoke FAILED: endpoint not STOPPED")
        time.sleep(0.1)
    stats = st["stats"]
    assert stats["rejected_total"] == 0, \
        f"serving smoke FAILED: rejected requests at smoke load: {stats}"
    assert stats["completed_total"] == 6, stats
    print("serving smoke OK:",
          {k: stats[k] for k in ("completed_total", "p50_latency_s",
                                 "mean_batch_occupancy")})
finally:
    core.close()
EOF

echo "== backend-parity + manifest test groups =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_backends.py tests/test_manifest.py

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
