"""Generate EXPERIMENTS.md from dry-run artifacts + the perf log.

Reads results/dryrun/*.json (+ .hlo.gz for roofline terms) and
results/perf_log.json (hillclimb iterations, appended by the perf pass),
and writes the full EXPERIMENTS.md: §Dry-run, §Roofline, §Perf,
§Paper-claims. Regenerable at any time:

  PYTHONPATH=src python -m benchmarks.make_experiments
"""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.roofline import (DCN_BW, HBM_BW, ICI_BW,  # noqa: E402
                                     KERNEL_SCOPES, PEAK_FLOPS,
                                     analyze_file, model_flops,
                                     roofline_row)
from repro.configs.base import SHAPES_BY_NAME, shapes_for  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_arch  # noqa: E402

RESULTS = ROOT / "results" / "dryrun"
PERF_LOG = ROOT / "results" / "perf_log.json"
OUT = ROOT / "EXPERIMENTS.md"


def load_cells():
    cells = {}
    for j in sorted(RESULTS.glob("*.json")):
        rec = json.loads(j.read_text())
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
               rec.get("tag", ""))
        cells[key] = rec
    return cells


def fmt_gib(b):
    return f"{b / 2 ** 30:.2f}"


def dryrun_table(cells):
    rows = ["| arch | shape | mesh | status | lower(s) | compile(s) | "
            "peak GiB/dev | XLA flops/dev (scan-once) |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            for mesh in ("single", "multi"):
                rec = cells.get((arch, shape, mesh, ""))
                if rec is None:
                    if shape == "long_500k" and not cfg.subquadratic:
                        rows.append(
                            f"| {arch} | {shape} | {mesh} | SKIP "
                            f"(quadratic attention) | — | — | — | — |")
                    continue
                if rec.get("status") == "skipped":
                    rows.append(f"| {arch} | {shape} | {mesh} | SKIP "
                                f"({rec.get('reason', '')[:40]}) "
                                f"| — | — | — | — |")
                    continue
                if rec.get("status") != "ok":
                    rows.append(f"| {arch} | {shape} | {mesh} | "
                                f"ERROR {rec.get('error', '')[:50]} "
                                f"| — | — | — | — |")
                    continue
                rows.append(
                    f"| {arch} | {shape} | {mesh} | ok "
                    f"| {rec['lower_s']} | {rec['compile_s']} "
                    f"| {fmt_gib(rec.get('peak_bytes_per_device', 0))} "
                    f"| {rec.get('xla_flops', 0):.3g} |")
    return "\n".join(rows)


def roofline_tables(cells):
    """Single-pod roofline per cell, reference + kernel accounting."""
    rows = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
            "dominant | MODEL_FLOPS | useful ratio | roofline frac | "
            "fix note |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    analyses = {}
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        for shape_spec in shapes_for(cfg):
            shape = shape_spec.name
            rec = cells.get((arch, shape, "single", ""))
            if not rec or rec.get("status") != "ok":
                continue
            hlo = rec.get("hlo")
            if not hlo or not Path(hlo).exists():
                continue
            try:
                a = analyze_file(hlo, KERNEL_SCOPES)
            except Exception as e:
                rows.append(f"| {arch} | {shape} | parse error "
                            f"{type(e).__name__} | | | | | | | |")
                continue
            row = roofline_row(rec, a, cfg, SHAPES_BY_NAME[shape], 256)
            analyses[(arch, shape)] = (a, row)
            note = _fix_note(row, rec)
            rows.append(
                f"| {arch} | {shape} | {row['compute_s']:.4f} "
                f"| {row['memory_s']:.4f} | {row['collective_s']:.4f} "
                f"| **{row['dominant']}** | {row['model_flops']:.3g} "
                f"| {row['useful_ratio']} | {row['roofline_frac']} "
                f"| {note} |")
    return "\n".join(rows), analyses


def _fix_note(row, rec):
    if row["dominant"] == "memory":
        return ("activation/remat traffic dominates: bigger fused "
                "(Pallas) regions, microbatching, bf16 residuals")
    if row["dominant"] == "collective":
        return ("TP activation psums dominate: sequence-parallel resharding"
                " / overlap collectives with compute")
    return "compute-bound: increase arithmetic intensity already high"


def perf_section():
    if not PERF_LOG.exists():
        return "_(perf log not yet recorded — run the hillclimb pass)_"
    log = json.loads(PERF_LOG.read_text())
    out = ["""The three hillclimbed cells (selection per assignment: worst roofline
fraction / most collective-bound / most representative of the paper's
technique). Baseline (paper-faithful layouts) and optimized (beyond-paper)
are recorded separately; every iteration below is a
hypothesis -> change -> re-lower -> re-measure cycle on the dry-run HLO.

**Headline (single-pod, 256 chips, roofline fraction = ideal/bound):**

| cell | paper-faithful (tp_dp) | fsdp_tp baseline | zero3_sp optimized | gain |
|---|---|---|---|---|
| qwen2-vl-2b train_4k | n/a (heads indivisible -> replicated attn) | 0.0120 | **0.1200** (zero3_sp+vjp) | **10.0x** |
| kimi-k2-1t-a32b train_4k | infeasible (replica >> HBM) | 0.0859 | **0.1237** (zero3_sp+vjp) @ 60 GiB | **+44%** |
| qwen1.5-110b train_4k | 0.2056 @ 309 GiB/chip (infeasible capacity) | 0.2183 | **0.2668** (fsdp_tp+vjp) | +22% |
| whisper-large-v3 train_4k (bonus) | n/a | 0.0136 | **0.1135** (zero3_sp+vjp) @ 7 GiB | **8.3x** |
| stablelm-1.6b train_4k (fleet effect) | 0.0432 | 0.0440 | **0.0652** (fsdp_tp+vjp) | +48% |

The final iteration (custom-VJP flash attention with an O(S)-memory tiled
backward) ships as the DEFAULT attention path, so the §Roofline baseline
table below already includes it — the per-cell logs keep the pre-VJP
numbers so the delta stays visible.

zero3_sp (beyond-paper) = the paper's PS partition scheme promoted to a
resident layout over BOTH mesh axes + sequence-parallel activations +
shard_map'd flash attention with compact-KV gathers. The paper-faithful
tp_dp column replicates the full model per 16-chip learner group and
PS-syncs over data — exactly the paper's deployment — and is capacity-
infeasible at >=110B, which is the quantified argument for the ZeRO
lineage of the paper's own partitioning idea.
"""]
    for cell in log.get("cells", []):
        out.append(f"### {cell['name']}\n")
        out.append(cell.get("why", ""))
        out.append("")
        out.append("| iter | hypothesis | change | dominant term before(s) "
                   "| after(s) | verdict |")
        out.append("|---|---|---|---|---|---|")
        for i, it in enumerate(cell.get("iters", [])):
            out.append(f"| {i} | {it['hypothesis']} | {it['change']} "
                       f"| {it['before']:.4f} | {it['after']:.4f} "
                       f"| {it['verdict']} |")
        out.append("")
        if "summary" in cell:
            out.append(cell["summary"])
        out.append("")
    return "\n".join(out)


HEADER = f"""# EXPERIMENTS

All numbers derive from the multi-pod dry-run (``launch/dryrun.py``:
lower + compile per cell on 512 forced host devices) and the HLO-level
roofline analyzer (``analysis/roofline.py``). Hardware model (TPU v5e):
{PEAK_FLOPS / 1e12:.0f} TFLOP/s bf16/chip, {HBM_BW / 1e9:.0f} GB/s HBM,
{ICI_BW / 1e9:.0f} GB/s/link ICI, {DCN_BW / 1e9:.1f} GB/s/chip DCN
(cross-pod). ``compiled.cost_analysis()`` counts scan bodies once
(verified) so the analyzer re-derives FLOPs/bytes with while-loop
trip-count multiplication; roofline terms use kernel-scope accounting
(regions that lower to single Pallas TPU kernels contribute FLOPs but not
HBM bytes — see DESIGN.md §7). MODEL_FLOPS = 6·N_active·T (+ attention /
SSD terms, kind-aware); "useful ratio" = MODEL_FLOPS/chips ÷ HLO FLOPs
per device; "roofline frac" = (MODEL_FLOPS/chips/peak) ÷ max(term) — the
score to push toward 1.

Regenerate with ``PYTHONPATH=src python -m benchmarks.make_experiments``.
"""


def paper_claims():
    return """
| paper claim | experiment | outcome |
|---|---|---|
| PS reduces O(L²) broadcast messages to O(L)≈2L | `bench_ps_vs_broadcast` (HLO ici bytes, L∈{4,8}) | byte ratio broadcast/PS = 2.50 at L=4, 4.50 at L=8 — matches the analytic (L+1)/2 exactly; tests/test_multidevice.py asserts >3x at L=8 |
| PS solvers: PSGD / model-averaging / EASGD (+Downpour trigger) | tests/test_solvers.py, `bench_solvers` | all four converge on the regression task; modelavg(H=1) ≡ PSGD bit-exactly; EASGD learner-center divergence shrinks; Downpour staleness measured |
| comm-frequency threshold (sync every N batches) | SolverConfig.comm_every; `bench_solvers` | modelavg/easgd reach target loss in 5 rounds × H=4 local steps (20 steps) vs PSGD 15 rounds/15 syncs — fewer syncs, more steps (the paper's trade) |
| global cursor gives mutually-exclusive chunks | hypothesis property test (tests/test_cursor.py) | any interleaving tiles [0,total) exactly; 8-thread stress passes |
| job survives learner crash; resumes from checkpoint | tests/test_fault_tolerance.py, test_system.py | injected container crash at step 17 → scheduler restart → resumes from step-10 checkpoint → COMPLETED; trained model uploaded |
| user-error jobs terminate w/o restart | tests/test_platform.py, test_system.py | UserError → JOB_FAILED via watchdog → LCM kills job, restarts == 0 |
| LCM decoupled via ZK (control plane can die) | tests/test_platform.py::test_lcm_statelessness_and_decoupling | job completes while LCM object destroyed; recovered LCM resumes from ZK |
| ZK replicated, needs majority | tests/test_zookeeper.py | writes survive 1/3 replica loss, fail (ConnectionLoss) at 2/3 |
| colloquium: 45 concurrent users, 200+ jobs | tests/test_system.py::test_scheduler_handles_colloquium_burst, `bench_scheduler` | 45 jobs from 15 concurrent submitters, heterogeneous GPU requests — 45/45 COMPLETED |
| unresponsive-GPU node keeps getting jobs (their bug) | tests/test_platform.py::test_colloquium_incident_without_health_checks | reproduced with health checks off (tasks fail to start), FIXED with the HealthChecker they list as future work (node drained) |
| hyperparameter tuning improves accuracy (71%→77%) | examples/hyperparam_sweep.py | 12-job sweep over lr/steps/learners: 50% → 100% on the synthetic task |
| checkpoint to object store, restart from it | tests/test_checkpoint.py + test_fault_tolerance.py | atomic publish, crc-validated restore, corrupt-checkpoint fallback |
| exponential backoff on storage failures | tests/test_fault_tolerance.py::test_objectstore_backoff_retries | 3 injected transient failures absorbed; delays grow geometrically |
"""


def main():
    cells = load_cells()
    dr = dryrun_table(cells)
    rt, _ = roofline_tables(cells)
    doc = "\n".join([
        HEADER,
        "\n## §Dry-run — every (arch x shape x mesh) lower+compile\n",
        f"{sum(1 for k, v in cells.items() if v.get('status') == 'ok' and not k[3])} "
        "cells compiled OK (16x16 single-pod AND 2x16x16 multi-pod).\n",
        dr,
        "\n## §Roofline — single-pod (256 chips), kernel-scope accounting\n",
        rt,
        "\n## §Perf — hillclimb log (hypothesis → change → measure)\n",
        perf_section(),
        "\n## §Paper-claims validation\n",
        paper_claims(),
    ])
    OUT.write_text(doc)
    print(f"wrote {OUT} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
