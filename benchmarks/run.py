"""Benchmark harness — one benchmark per paper claim/table.

Prints ``name,us_per_call,derived`` CSV. Benches run on the real single
CPU device; anything needing multiple devices (collective byte counts)
spawns a subprocess with forced host devices, mirroring the dry-run.

  ps_vs_broadcast_L{4,8}   paper §Learner Coordination: O(L) vs O(L^2)
                           bytes from compiled HLO (derived = byte ratio)
  software_ps_round        paper §Parameter Server throughput-critical path
  solver_*                 paper §PS solvers: rounds to reach loss<0.05
  scheduler_colloquium     paper §Usage Study: 45 users / 135 jobs burst
  cursor_claims            paper §Global Cursor: claims/s (8 threads)
  kernel_*                 Pallas kernels (interpret) vs jnp oracle
  checkpoint_save/restore  paper §Fault tolerance: MB/s
  quantize_throughput      gradient compression: MB/s + compression ratio
  rest_api                 paper §API layer: requests/s
  roofline_table           §Roofline summary over results/dryrun artifacts
  backends                 execution backends (software-ps vs pjit) on one
                           smoke manifest: steps/s + time-to-first-
                           checkpoint -> BENCH_backends.json at repo root
  ps_dataplane             software-PS data plane: compression none vs
                           int8 on the same smoke manifest: steps/s,
                           bytes on wire, fused-aggregation ms/round,
                           final-loss delta -> BENCH_ps_dataplane.json
                           (env: PS_DATAPLANE_STEPS, PS_DATAPLANE_OUT
                           for the scripts/verify.sh smoke invocation)
  serving                  inference endpoint (serving subsystem) under
                           closed-loop client load at 2-3 offered
                           concurrencies: req/s, p50/p99 latency, mean
                           batch occupancy -> BENCH_serving.json
                           (env: SERVING_LOADS, SERVING_REQUESTS,
                           SERVING_OUT)

Pass bench-name substrings as argv to run a subset, e.g.
``python benchmarks/run.py backends`` or
``python benchmarks/run.py ps-dataplane``.

``python benchmarks/run.py gate`` is the perf regression gate: it
re-runs the three trajectory benches (backends, ps_dataplane, serving)
into a temp dir and compares every rate metric against the committed
BENCH_*.json baselines with a wide tolerance band
(``GATE_TOLERANCE``, default 0.5 — container speed varies several-fold
between runs, so the gate catches collapses, not noise). Exit 1 iff a
metric regresses; the final ``GATE {...}`` line is machine-readable.
``GATE_BENCHES`` subsets the gated files.
"""
import json
import subprocess
import sys
import threading
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

ROWS = []


def emit(name, us, derived=""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def timeit(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------


def bench_ps_vs_broadcast():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, re, json
sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from repro.core.solvers import SolverConfig, make_solver
from repro.optim.optimizers import OptConfig
from repro.launch.mesh import make_mesh
from repro.analysis.roofline import analyze_hlo_text

out = {}
for nl in (4, 8):
    mesh = make_mesh(data=nl, model=1)
    D = 4096
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    p0 = {"w": jnp.zeros((D,))}
    batches = {"x": jnp.zeros((1, nl, 4, D)), "y": jnp.zeros((1, nl, 4))}
    res = {}
    for mode in ("ps", "broadcast"):
        s = make_solver(loss, p0, OptConfig(name="sgd"),
                        SolverConfig(name="psgd", push_mode=mode), nl,
                        mesh=mesh)
        st = s.init_state(p0)
        txt = jax.jit(s._round).lower(st, batches).compile().as_text()
        a = analyze_hlo_text(txt)
        res[mode] = a["ici_bytes_per_device"]
    out[nl] = res
print("RESULT " + json.dumps(out))
""" % str(ROOT / "src")
    t0 = time.perf_counter()
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    us = (time.perf_counter() - t0) * 1e6
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")]
    if not line:
        emit("ps_vs_broadcast", us, f"ERROR:{p.stderr[-200:]}")
        return
    res = json.loads(line[0][7:])
    for nl, r in sorted(res.items()):
        ratio = r["broadcast"] / max(r["ps"], 1)
        emit(f"ps_vs_broadcast_L{nl}", us / len(res),
             f"bytes_ps={r['ps']:.0f};bytes_bc={r['broadcast']:.0f};"
             f"ratio={ratio:.2f}")


def bench_software_ps():
    from repro.core.software_ps import SoftwareParameterServer
    f = 1 << 20
    init = np.zeros(f, np.float32)
    ps = SoftwareParameterServer(init, n_shards=4, n_learners=4,
                                 optimizer="adam", lr=1e-3)
    for i in range(4):
        ps.join(i)
    g = [np.random.randn(f).astype(np.float32) for _ in range(4)]

    def round_():
        ts = [threading.Thread(target=ps.push, args=(i, g[i]))
              for i in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        ps.pull(0)

    us = timeit(round_, n=5)
    mbps = (4 * g[0].nbytes + init.nbytes) / (us / 1e6) / 1e6
    emit("software_ps_round", us, f"agg_MBps={mbps:.0f}")


def bench_solvers():
    import jax
    import jax.numpy as jnp
    from repro.core.solvers import SolverConfig, make_solver
    from repro.optim.optimizers import OptConfig
    D, NL, B = 16, 4, 16
    W = jax.random.normal(jax.random.PRNGKey(0), (D,))
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    p0 = {"w": jnp.zeros((D,))}

    def batches(rng, h):
        xs = jax.random.normal(rng, (h, NL, B, D))
        return {"x": xs, "y": xs @ W}

    for scfg in (SolverConfig(name="psgd"),
                 SolverConfig(name="psgd", compress=True),
                 SolverConfig(name="modelavg", comm_every=4),
                 SolverConfig(name="easgd", comm_every=4),
                 SolverConfig(name="downpour", comm_every=4)):
        s = make_solver(loss, p0, OptConfig(name="sgd", lr=0.1), scfg, NL)
        st = s.init_state(p0)
        rng = jax.random.PRNGKey(1)
        rounds = 0
        t0 = time.perf_counter()
        m = {"loss": 1e9}
        while float(m["loss"]) > 0.05 and rounds < 400:
            rng, k = jax.random.split(rng)
            st, m = s.round(st, batches(k, scfg.rounds_h))
            rounds += 1
        us = (time.perf_counter() - t0) / max(rounds, 1) * 1e6
        tag = scfg.name + ("_q8" if scfg.compress else "")
        emit(f"solver_{tag}", us,
             f"rounds_to_0.05={rounds};steps={rounds * scfg.rounds_h};"
             f"wire_B_per_round={s.wire_bytes_per_round()}")


def bench_scheduler():
    import tempfile

    from repro.service.core import DLaaSCore, default_cluster
    wd = tempfile.mkdtemp(prefix="dlaas_bench_")
    core = DLaaSCore(wd, cluster=default_cluster(16, 8),
                     tick_interval=0.002)
    MAN = ("name: b\nlearners: 1\ngpus: %d\nsteps: 1\n"
           "framework:\n  name: repro-mlp\n  d_in: 8\n  n_classes: 2\n")
    try:
        t0 = time.perf_counter()
        tids = []
        lock = threading.Lock()

        def user(u):
            mid = core.deploy_model(MAN % (1 + u % 3),
                                    user=f"u{u}")["model_id"]
            got = [core.create_training(mid, user=f"u{u}")["training_id"]
                   for _ in range(3)]
            with lock:
                tids.extend(got)

        ts = [threading.Thread(target=user, args=(u,)) for u in range(15)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        done = sum(1 for t in tids
                   if core.wait_for(t, timeout=240) == "COMPLETED")
        dt = time.perf_counter() - t0
        emit("scheduler_colloquium", dt / max(len(tids), 1) * 1e6,
             f"jobs={len(tids)};completed={done};makespan_s={dt:.1f};"
             f"jobs_per_s={len(tids) / dt:.1f}")
    finally:
        core.close()


def bench_cursor():
    from repro.core.cursor import GlobalCursor
    from repro.platform.zookeeper import ZooKeeper
    cur = GlobalCursor(ZooKeeper(), "/c", 10 ** 9)
    n = 2000

    def claims():
        ts = []
        for _ in range(8):
            t = threading.Thread(
                target=lambda: [cur.next_chunk(16)
                                for _ in range(n // 8)])
            ts.append(t)
        [t.start() for t in ts]
        [t.join() for t in ts]

    us = timeit(claims, n=3)
    emit("cursor_claims", us / n, f"claims_per_s={n / (us / 1e6):.0f}")


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.models.attention import flash_attention_ref, repeat_kv

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64))
    o1 = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o2 = flash_attention_ref(q, repeat_kv(k, 4), repeat_kv(v, 4),
                             causal=True, q_chunk=64, k_chunk=64)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    us = timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, causal=True, block_q=64,
                            block_k=64)), n=3)
    emit("kernel_flash_attn_interp", us, f"allclose_err={err:.2e}")

    x = jax.random.normal(jax.random.PRNGKey(3), (1, 256, 4, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4),
                                           (1, 256, 4)))
    b = jax.random.normal(jax.random.PRNGKey(5), (1, 256, 1, 16)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(6), (1, 256, 1, 16)) * 0.3
    from repro.models.mamba import ssd_scan_ref
    y1 = ops.ssd_scan(x, dt, jnp.zeros(4), b, c, chunk=64)
    y2, _ = ssd_scan_ref(x, dt, jnp.zeros(4), b, c, chunk=64)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    us = timeit(lambda: jax.block_until_ready(
        ops.ssd_scan(x, dt, jnp.zeros(4), b, c, chunk=64)), n=3)
    emit("kernel_ssd_scan_interp", us, f"allclose_err={err:.2e}")

    g = jax.random.normal(jax.random.PRNGKey(7), (4, 1 << 16))
    p = jax.random.normal(jax.random.PRNGKey(8), (1 << 16,))
    m = jnp.zeros(1 << 16)
    us = timeit(lambda: jax.block_until_ready(
        ops.ps_aggregate(g, p, m, m, 1, solver="adam")), n=3)
    emit("kernel_ps_aggregate_interp", us,
         f"elems_per_s={(1 << 16) / (us / 1e6):.2e}")


def bench_checkpoint():
    import tempfile

    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import CheckpointManager
    d = tempfile.mkdtemp(prefix="ckpt_bench_")
    tree = {"w": jnp.zeros((1 << 22,), jnp.float32)}      # 16 MB
    cm = CheckpointManager(d, async_save=False)
    us_save = timeit(lambda: cm.save(1, tree), n=3)
    emit("checkpoint_save_16MB", us_save,
         f"MBps={16 / (us_save / 1e6):.0f}")
    us_restore = timeit(lambda: cm.restore(1, tree), n=3)
    emit("checkpoint_restore_16MB", us_restore,
         f"MBps={16 / (us_restore / 1e6):.0f}")


def bench_quantize():
    import jax
    import jax.numpy as jnp
    from repro.core.compression import compress_with_feedback, wire_bytes
    x = jax.random.normal(jax.random.PRNGKey(0), (1 << 22,))
    e = jnp.zeros_like(x)
    fn = jax.jit(lambda x, e: compress_with_feedback(x, e))
    jax.block_until_ready(fn(x, e))
    us = timeit(lambda: jax.block_until_ready(fn(x, e)), n=5)
    ratio = (x.size * 4) / wire_bytes(x.size)
    emit("quantize_throughput", us,
         f"MBps={x.size * 4 / (us / 1e6) / 1e6:.0f};"
         f"compression={ratio:.2f}x")


def bench_rest_api():
    import tempfile
    import urllib.request

    from repro.service.rest import DLaaSServer
    wd = tempfile.mkdtemp(prefix="dlaas_rest_")
    with DLaaSServer(wd) as srv:
        man = ("name: x\nlearners: 1\nsteps: 1\n"
               "framework:\n  name: repro-mlp\n")
        body = json.dumps({"manifest": man}).encode()

        def call():
            req = urllib.request.Request(
                f"{srv.url}/v1/models", data=body, method="POST")
            req.add_header("Content-Type", "application/json")
            urllib.request.urlopen(req).read()

        us = timeit(call, n=20)
        emit("rest_api_deploy", us, f"rps={1e6 / us:.0f}")


def bench_backends():
    """Backend trajectory: the same smoke manifest trained through both
    execution backends (runtime/backend.py); emits BENCH_backends.json
    at the repo root with steps/s and time-to-first-checkpoint
    (``BACKENDS_OUT`` redirects it, e.g. for the perf gate)."""
    import os
    import tempfile

    from repro.service.core import DLaaSCore
    out_path = Path(os.environ.get("BACKENDS_OUT",
                                   ROOT / "BENCH_backends.json"))
    MAN = ("name: bench-backends\nlearners: 1\ngpus: 1\nsteps: 30\n"
           "checkpoint_every: 10\nlr: 0.1\noptimizer: sgd\nseed: 0\n"
           "batch_docs: 4\n"
           "data:\n  n_docs: 128\n  seq_len: 16\n"
           "framework:\n  name: repro-lm\n  arch: stablelm-1.6b\n"
           "  distribution: %s\n")
    out = {}
    for backend in ("software-ps", "pjit"):
        core = DLaaSCore(tempfile.mkdtemp(prefix=f"bench_{backend}_"),
                         tick_interval=0.005)
        try:
            mid = core.deploy_model(MAN % backend)["model_id"]
            t0 = time.time()
            tid = core.create_training(mid)["training_id"]
            status = core.wait_for(tid, timeout=300)
            wall = time.time() - t0
            evs = core.metrics.events(tid, "checkpoint")
            ttfc = evs[0]["ts"] - t0 if evs else None
            loss = core.metrics.series(tid, "loss")
            steps = len(loss.values)
            row = {"status": status, "steps": steps,
                   "wall_s": round(wall, 3),
                   "steps_per_s": round(steps / wall, 2),
                   "time_to_first_checkpoint_s":
                       round(ttfc, 3) if ttfc is not None else None,
                   "final_loss": (round(loss.values[-1], 4)
                                  if loss.values else None)}
            out[backend] = row
            emit(f"backend_{backend}", wall / max(steps, 1) * 1e6,
                 f"steps_per_s={row['steps_per_s']};"
                 f"ttfc_s={row['time_to_first_checkpoint_s']};"
                 f"final_loss={row['final_loss']}")
        finally:
            core.close()
    out_path.write_text(
        json.dumps({"manifest": "repro-lm/stablelm-1.6b smoke, 30 steps",
                    "note": ("both backends measured in one process on "
                             "the same machine — compare within a file, "
                             "not across commits: container speed varies "
                             "several-fold between runs, and the jax "
                             "persistent compile cache (DLAAS_JAX_CACHE) "
                             "makes repeat invocations warm-start"),
                    "backends": out}, indent=1) + "\n")


def bench_ps_dataplane():
    """Data-plane trajectory: the backends smoke manifest through the
    software-PS with compression none vs int8. Emits
    BENCH_ps_dataplane.json with steps/s, bytes on the wire (pre/post
    compression), fused-aggregation ms/round and the compressed-vs-
    uncompressed final-loss delta. ``PS_DATAPLANE_STEPS`` /
    ``PS_DATAPLANE_OUT`` shrink + redirect it for CI smoke runs."""
    import os
    import tempfile

    from repro.service.core import DLaaSCore
    steps = int(os.environ.get("PS_DATAPLANE_STEPS", "30"))
    out_path = Path(os.environ.get("PS_DATAPLANE_OUT",
                                   ROOT / "BENCH_ps_dataplane.json"))
    MAN = ("name: bench-ps-dataplane\nlearners: 1\ngpus: 1\n"
           f"steps: {steps}\n"
           "checkpoint_every: 1000000\nlr: 0.1\noptimizer: sgd\nseed: 0\n"
           "batch_docs: 4\n"
           "data:\n  n_docs: 128\n  seq_len: 16\n"
           "framework:\n  name: repro-lm\n  arch: stablelm-1.6b\n"
           "  distribution: software-ps\n  compression: %s\n")
    out = {}
    for comp in ("none", "int8"):
        core = DLaaSCore(tempfile.mkdtemp(prefix=f"bench_dp_{comp}_"),
                         tick_interval=0.005)
        try:
            mid = core.deploy_model(MAN % comp)["model_id"]
            t0 = time.time()
            tid = core.create_training(mid)["training_id"]
            status = core.wait_for(tid, timeout=300)
            wall = time.time() - t0
            loss = core.metrics.series(tid, "loss")
            dp = core.training_status(tid).get("data_plane") or {}
            n = len(loss.values)
            # per-step loss swings ~±5% with batch noise; the quality
            # comparison uses a tail-window mean so it measures the
            # trajectory, not one noisy sample
            tail = loss.values[-min(10, max(1, n // 3)):]
            row = {"status": status, "steps": n,
                   "wall_s": round(wall, 3),
                   "steps_per_s": round(n / wall, 2),
                   "final_loss": (round(sum(tail) / len(tail), 4)
                                  if tail else None),
                   "last_step_loss": (round(loss.values[-1], 4)
                                      if loss.values else None),
                   "bytes_pushed_wire": dp.get("bytes_pushed_wire"),
                   "bytes_pushed_dense": dp.get("bytes_pushed_dense"),
                   "compression_ratio": dp.get("compression_ratio"),
                   "agg_ms_per_round": dp.get("agg_ms_per_round")}
            out[comp] = row
            emit(f"ps_dataplane_{comp}", wall / max(n, 1) * 1e6,
                 f"steps_per_s={row['steps_per_s']};"
                 f"wire_ratio={row['compression_ratio']};"
                 f"agg_ms={row['agg_ms_per_round']};"
                 f"final_loss={row['final_loss']}")
        finally:
            core.close()
    summary = {"manifest": f"repro-lm/stablelm-1.6b smoke, {steps} steps",
               "pr2_baseline_steps_per_s": 3.49,
               "modes": out}
    ln, li = out["none"]["final_loss"], out["int8"]["final_loss"]
    if ln and li:
        summary["final_loss_rel_delta"] = round(abs(li - ln) / abs(ln), 4)
    wn = out["int8"]
    if wn["bytes_pushed_wire"]:
        summary["wire_bytes_reduction"] = round(
            wn["bytes_pushed_dense"] / wn["bytes_pushed_wire"], 3)
    out_path.write_text(json.dumps(summary, indent=1) + "\n")


def bench_serving():
    """Serving trajectory: one smoke-arch inference endpoint under
    closed-loop client load at increasing offered concurrency. Emits
    BENCH_serving.json with req/s, p50/p99 request latency and mean
    batch occupancy per load (occupancy measured from the engine's
    occupied-slot-steps delta, so each load reports its own window).
    ``SERVING_LOADS`` / ``SERVING_REQUESTS`` / ``SERVING_OUT`` shrink +
    redirect it for CI smoke runs."""
    import os
    import tempfile

    from repro.service.core import DLaaSCore
    loads = [int(x) for x in
             os.environ.get("SERVING_LOADS", "1,3,6").split(",")]
    n_req = int(os.environ.get("SERVING_REQUESTS", "18"))
    out_path = Path(os.environ.get("SERVING_OUT",
                                   ROOT / "BENCH_serving.json"))
    prompt_len, max_new, capacity = 12, 8, 3
    core = DLaaSCore(tempfile.mkdtemp(prefix="bench_serving_"),
                     tick_interval=0.005)
    rows = {}
    try:
        eid = core.deploy_endpoint(
            arch="stablelm-1.6b", capacity=capacity,
            max_queue=max(64, n_req), max_new=max_new)["endpoint_id"]
        t0 = time.time()
        while core.endpoint_status(eid)["state"] != "READY":
            if time.time() - t0 > 300:
                raise RuntimeError("endpoint never became READY")
            time.sleep(0.05)
        # warm the prefill jit for the bench prompt length so the first
        # load isn't dominated by one compile
        core.predict(eid, np.arange(prompt_len) + 1, max_new=1)
        for load in loads:
            before = core.endpoint_status(eid)["stats"]
            lats, lock = [], threading.Lock()
            rng = np.random.RandomState(load)
            prompts = [rng.randint(0, 100, size=prompt_len)
                       for _ in range(n_req)]

            def client(idx, load=load, prompts=prompts, lats=lats,
                       lock=lock):
                for i in range(idx, n_req, load):
                    t1 = time.time()
                    core.predict(eid, prompts[i], max_new=max_new)
                    with lock:
                        lats.append(time.time() - t1)

            t1 = time.time()
            ts = [threading.Thread(target=client, args=(k,))
                  for k in range(load)]
            [t.start() for t in ts]
            [t.join() for t in ts]
            wall = time.time() - t1
            after = core.endpoint_status(eid)["stats"]
            d_steps = after["decode_steps"] - before["decode_steps"]
            d_occ = (after["occupied_slot_steps"]
                     - before["occupied_slot_steps"])
            lats.sort()
            row = {
                "offered_clients": load, "requests": n_req,
                "wall_s": round(wall, 3),
                "req_per_s": round(n_req / wall, 2),
                "p50_latency_s": round(lats[len(lats) // 2], 4),
                "p99_latency_s": round(
                    lats[max(0, int(np.ceil(0.99 * len(lats))) - 1)], 4),
                "mean_batch_occupancy": round(
                    d_occ / (d_steps * capacity), 4) if d_steps else None,
                "rejected": after["rejected_total"]
                - before["rejected_total"],
            }
            rows[str(load)] = row
            emit(f"serving_load{load}", wall / n_req * 1e6,
                 f"req_per_s={row['req_per_s']};"
                 f"p50_s={row['p50_latency_s']};"
                 f"p99_s={row['p99_latency_s']};"
                 f"occupancy={row['mean_batch_occupancy']}")
        core.stop_endpoint(eid)
        t0 = time.time()
        while core.endpoint_status(eid)["state"] != "STOPPED" \
                and time.time() - t0 < 60:
            time.sleep(0.05)
    finally:
        core.close()
    out_path.write_text(json.dumps({
        "arch": "stablelm-1.6b smoke",
        "capacity": capacity, "prompt_len": prompt_len,
        "max_new": max_new,
        "note": ("closed-loop clients on one host; compare loads within "
                 "a file, not across commits — container speed varies "
                 "and the jax compile cache warm-starts repeats"),
        "loads": rows}, indent=1) + "\n")


def bench_roofline_table():
    """Summarise §Roofline over existing dry-run artifacts (if present)."""
    from repro.analysis.roofline import (KERNEL_SCOPES, analyze_file,
                                         model_flops, roofline_row)
    from repro.configs.base import SHAPES_BY_NAME
    from repro.configs.registry import get_arch
    d = ROOT / "results" / "dryrun"
    hlos = sorted(d.glob("*__single.hlo.gz")) if d.exists() else []
    if not hlos:
        emit("roofline_table", 0.0, "no_artifacts(run launch/dryrun first)")
        return
    t0 = time.perf_counter()
    worst = (None, 1.0)
    for h in hlos:
        parts = h.name.replace(".hlo.gz", "").split("__")
        if len(parts) != 3 or parts[2] != "single":
            continue
        arch, shape = parts[0], parts[1]
        try:
            a = analyze_file(str(h), KERNEL_SCOPES)
            row = roofline_row({}, a, get_arch(arch),
                               SHAPES_BY_NAME[shape], 256)
            emit(f"roofline[{arch}|{shape}]",
                 max(a["compute_s"], a["memory_s"],
                     a["collective_s"]) * 1e6,
                 f"dom={row['dominant']};frac={row['roofline_frac']};"
                 f"useful={row['useful_ratio']}")
            if row["roofline_frac"] < worst[1]:
                worst = (f"{arch}|{shape}", row["roofline_frac"])
        except Exception as e:
            emit(f"roofline[{arch}|{shape}]", 0.0,
                 f"ERROR:{type(e).__name__}")
    emit("roofline_table", (time.perf_counter() - t0) * 1e6,
         f"cells={len(hlos)};worst={worst[0]}:{worst[1]}")


# ---------------------------------------------------------------------------
# perf regression gate — compare fresh runs of the trajectory benches
# against the committed BENCH_*.json baselines.

GATE_FILES = {
    "backends": "BENCH_backends.json",
    "ps_dataplane": "BENCH_ps_dataplane.json",
    "serving": "BENCH_serving.json",
}
GATE_OUT_ENV = {
    "backends": "BACKENDS_OUT",
    "ps_dataplane": "PS_DATAPLANE_OUT",
    "serving": "SERVING_OUT",
}


def gate_metrics(doc):
    """Flatten one BENCH_*.json into its higher-is-better rate metrics:
    ``backends.*.steps_per_s``, ``modes.*.{steps_per_s,
    compression_ratio}``, ``loads.*.req_per_s``."""
    out = {}
    for b, row in (doc.get("backends") or {}).items():
        out[f"backends.{b}.steps_per_s"] = row.get("steps_per_s")
    for m, row in (doc.get("modes") or {}).items():
        out[f"modes.{m}.steps_per_s"] = row.get("steps_per_s")
        out[f"modes.{m}.compression_ratio"] = row.get("compression_ratio")
    for ld, row in (doc.get("loads") or {}).items():
        out[f"loads.{ld}.req_per_s"] = row.get("req_per_s")
    return {k: v for k, v in out.items() if v}


def compare(baseline, fresh, tolerance):
    """Pure gate verdict for one bench file. Every rate metric present
    in ``baseline`` must be matched by ``fresh`` at
    ``fresh >= tolerance * baseline`` (all metrics are higher-is-
    better). The tolerance band is deliberately wide by default: the
    baselines' own notes warn that container speed varies several-fold
    between runs, so the gate catches collapses (a kernel accidentally
    falling off its tuned path), not single-digit-percent noise.

    Returns ``{"verdict": "PASS"|"REGRESS"|"MISSING_BASELINE",
    "tolerance": ..., "checks": [{metric, baseline, fresh, ratio,
    ok}, ...]}``."""
    if not baseline:
        return {"verdict": "MISSING_BASELINE", "tolerance": tolerance,
                "checks": []}
    base_m, fresh_m = gate_metrics(baseline), gate_metrics(fresh or {})
    checks, regressed = [], False
    for k, bv in sorted(base_m.items()):
        fv = fresh_m.get(k)
        if fv is None:
            checks.append({"metric": k, "baseline": bv, "fresh": None,
                           "ok": False})
            regressed = True
            continue
        ok = fv >= tolerance * bv
        checks.append({"metric": k, "baseline": bv, "fresh": fv,
                       "ratio": round(fv / bv, 3), "ok": ok})
        regressed = regressed or not ok
    return {"verdict": "REGRESS" if regressed else "PASS",
            "tolerance": tolerance, "checks": checks}


def run_gate(kinds=None) -> int:
    """``python benchmarks/run.py gate [kinds...]``: re-run the
    trajectory benches into a temp dir and compare each against its
    committed baseline. ``GATE_TOLERANCE`` (default 0.5: fresh must
    reach half the baseline rate) widens/narrows the band;
    ``GATE_BENCHES`` subsets the files. Prints per-check lines plus a
    final machine-readable ``GATE {...}`` JSON line; exit 1 iff any
    file regresses (a missing baseline is advisory, not fatal)."""
    import os
    import tempfile
    tol = float(os.environ.get("GATE_TOLERANCE", "0.5"))
    kinds = [k.replace("-", "_") for k in
             (kinds or os.environ.get(
                 "GATE_BENCHES", "backends,ps_dataplane,serving"
             ).split(","))]
    bad = [k for k in kinds if k not in GATE_FILES]
    if bad:
        print(f"gate: unknown bench kind(s) {bad}; "
              f"choose from {sorted(GATE_FILES)}", file=sys.stderr)
        return 2
    benches = {"backends": bench_backends,
               "ps_dataplane": bench_ps_dataplane,
               "serving": bench_serving}
    tmp = Path(tempfile.mkdtemp(prefix="dlaas_gate_"))
    report = {"tolerance": tol, "files": {}}
    verdict = "PASS"
    print("name,us_per_call,derived")
    for kind in kinds:
        base_path = ROOT / GATE_FILES[kind]
        baseline = (json.loads(base_path.read_text())
                    if base_path.exists() else None)
        fresh_path = tmp / GATE_FILES[kind]
        prev = os.environ.get(GATE_OUT_ENV[kind])
        os.environ[GATE_OUT_ENV[kind]] = str(fresh_path)
        try:
            benches[kind]()
        except Exception as e:          # fresh run died -> all checks fail
            print(f"gate[{kind}] bench error: {type(e).__name__}: {e}",
                  file=sys.stderr)
        finally:
            if prev is None:
                os.environ.pop(GATE_OUT_ENV[kind], None)
            else:
                os.environ[GATE_OUT_ENV[kind]] = prev
        fresh = (json.loads(fresh_path.read_text())
                 if fresh_path.exists() else None)
        res = compare(baseline, fresh, tol)
        report["files"][kind] = res
        if res["verdict"] == "REGRESS":
            verdict = "REGRESS"
        elif res["verdict"] == "MISSING_BASELINE" and verdict == "PASS":
            verdict = "MISSING_BASELINE"
        for c in res["checks"]:
            mark = "ok" if c["ok"] else "REGRESS"
            print(f"gate[{kind}] {c['metric']}: "
                  f"{c['fresh']} vs {c['baseline']} "
                  f"(ratio={c.get('ratio')}, need>={tol}) {mark}",
                  flush=True)
        if res["verdict"] == "MISSING_BASELINE":
            print(f"gate[{kind}] MISSING_BASELINE: "
                  f"commit {GATE_FILES[kind]} first", flush=True)
    report["verdict"] = verdict
    print("GATE " + json.dumps(report), flush=True)
    return 1 if verdict == "REGRESS" else 0


def main(only=None) -> None:
    benches = [
        bench_software_ps, bench_solvers, bench_cursor,
        bench_checkpoint, bench_quantize, bench_kernels,
        bench_rest_api, bench_backends, bench_ps_dataplane,
        bench_serving,
        bench_scheduler, bench_ps_vs_broadcast, bench_roofline_table,
    ]
    if only:
        only = [s.replace("-", "_") for s in only]
        benches = [b for b in benches
                   if any(s in b.__name__ for s in only)]
    print("name,us_per_call,derived")
    for b in benches:
        try:
            b()
        except Exception as e:  # keep the harness running
            emit(b.__name__, 0.0, f"ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    if sys.argv[1:2] == ["gate"]:
        sys.exit(run_gate(sys.argv[2:] or None))
    main(sys.argv[1:])
