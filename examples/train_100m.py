"""Train a ~100M-parameter LM with the production (pjit) trainer.

Defaults are sized for a CPU demo (--steps 10); on real hardware run the
full few-hundred-step command:

  PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 32

The config is a cut of stablelm-1.6b at ~100M params (12L, d=768,
vocab 16384). Checkpoints + restart work exactly as at full scale.
"""
import argparse
import dataclasses
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.registry import get_arch  # noqa: E402
from repro.distributed.sharding import Dist  # noqa: E402
from repro.optim.optimizers import OptConfig  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch("stablelm-1.6b"),
        arch_id="stablelm-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=16384,
        dtype="float32")
    print(f"model: {cfg.arch_id}  params={cfg.n_params() / 1e6:.1f}M")

    tc = TrainerConfig(
        batch=args.batch, seq=args.seq, ckpt_every=max(args.steps // 4, 5),
        ckpt_dir=args.ckpt_dir or tempfile.mkdtemp(prefix="train100m_"),
        job_id="train-100m")
    tr = Trainer(cfg, Dist(), OptConfig(name="adamw", lr=args.lr), tc,
                 opts={"remat": "none"}).init(0)
    t0 = time.time()
    losses = tr.train(args.steps)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s, {6 * cfg.n_params() * toks / dt / 1e9:.1f} GFLOP/s)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"checkpoints at {tc.ckpt_dir}: steps {tr.ckpt.steps()}")
    assert losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
