"""Two tenants contending for GPUs: priorities, fair-share, preemption.

A 2-GPU cluster. Tenant 'research' fills it with a long low-priority
job; tenant 'prod' then submits a short high-priority job that cannot
fit. The scheduler preempts the research job (it exits at a step
boundary, after its last checkpoint), runs the prod job, then re-places
the research job, which resumes from its checkpoint and completes —
no tenant monopolizes the cluster, and nobody loses work.

  PYTHONPATH=src python examples/multitenant_contention.py
"""
import json
import sys
import tempfile
import time
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.platform.cluster import Cluster, Node, Resources  # noqa: E402
from repro.service.rest import DLaaSServer                   # noqa: E402

MANIFEST = """\
name: contention-model
version: "1.0"
description: tiny classifier; long enough to be preempted mid-flight
learners: 1
gpus: 2
memory: 1024MiB
steps: 400
checkpoint_every: 10
lr: 0.2
data_stores:
  - id: objectstore
    type: softlayer_objectstore
    training_data:
      container: my_training_data
framework:
  name: repro-mlp
  d_in: 16
  n_classes: 4
"""


def req(url, method="GET", body=None, token="demo"):
    data = json.dumps(body).encode() if body is not None else None
    r = urllib.request.Request(url, data=data, method=method)
    r.add_header("Authorization", f"Bearer {token}")
    if data:
        r.add_header("Content-Type", "application/json")
    with urllib.request.urlopen(r) as resp:
        return json.loads(resp.read())


def main():
    wd = tempfile.mkdtemp(prefix="dlaas_contention_")
    cluster = Cluster([Node("n0", Resources(cpus=16, gpus=2,
                                            memory_mb=64000))])
    with DLaaSServer(wd, cluster=cluster) as srv:
        print(f"DLaaS at {srv.url} — one node, 2 GPUs")
        mid = req(f"{srv.url}/v1/models", "POST",
                  {"manifest": MANIFEST})["model_id"]

        # research takes the whole cluster with a low-priority job
        lo = req(f"{srv.url}/v1/trainings", "POST",
                 {"model_id": mid, "tenant": "research", "priority": 0},
                 token="research-user")["training_id"]
        print(f"[research] {lo} started (priority 0, 2 GPUs, 400 steps)")
        while not srv.core.metrics.checkpoints(lo):
            time.sleep(0.02)
        steps = srv.core.training_status(lo)["steps_done"]
        print(f"[research] checkpointed, {steps} steps done")

        # prod submits a short high-priority job — no GPUs left
        hi = req(f"{srv.url}/v1/trainings", "POST",
                 {"model_id": mid, "tenant": "prod", "priority": 10,
                  "overrides": {"steps": 60}},
                 token="prod-user")["training_id"]
        print(f"[prod]     {hi} submitted (priority 10) -> preempting")

        seen = set()
        while True:
            lo_state = req(f"{srv.url}/v1/trainings/{lo}")["status"]
            hi_state = req(f"{srv.url}/v1/trainings/{hi}")["status"]
            key = (lo_state, hi_state)
            if key not in seen:
                seen.add(key)
                print(f"    research={lo_state:<10} prod={hi_state}")
                if lo_state == "PREEMPTED":
                    q = req(f"{srv.url}/v1/queue")["queue"]
                    print(f"    queue: {q}")
            if lo_state == "COMPLETED" and hi_state == "COMPLETED":
                break
            time.sleep(0.05)

        st = req(f"{srv.url}/v1/trainings/{lo}")
        logs = req(f"{srv.url}/v1/trainings/{lo}/logs")["logs"]
        resumed = [l for l in logs if "resumed from checkpoint" in l]
        print(f"[research] completed: steps={st['steps_done']} "
              f"last_loss={st['last_loss']:.4f}")
        print(f"[research] {resumed[0] if resumed else 'NO RESUME LOG?'}")

        tenants = req(f"{srv.url}/v1/tenants")
        for name in ("research", "prod"):
            t = tenants[name]
            print(f"[{name}] gpu_seconds={t['gpu_seconds']:.2f} "
                  f"placements={t['placements']} "
                  f"preemptions={t['preemptions']}")
        assert resumed and st["steps_done"] >= 400
        assert tenants["research"]["preemptions"] >= 1
    print("OK")


if __name__ == "__main__":
    main()
