import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
# ^ before jax import: this example demonstrates multi-device elasticity
#   on 8 simulated host devices.

"""Elastic scaling + failover with the production trainer.

Phase 1: train on a 4x2 (data x model) mesh.
Phase 2: two "nodes" leave the pool -> resume on 2x2 (checkpointed state
         is resharded onto the new mesh via device_put).
Phase 3: simulated coordinator crash -> a brand-new Trainer restores from
         the latest valid checkpoint and finishes the run.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import reduce_for_smoke  # noqa: E402
from repro.configs.registry import get_arch  # noqa: E402
from repro.distributed.sharding import Dist  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.optim.optimizers import OptConfig  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    cfg = reduce_for_smoke(get_arch("stablelm-1.6b"))
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    tc = TrainerConfig(batch=8, seq=32, ckpt_every=10, ckpt_dir=ckpt)
    opt = OptConfig(name="adamw", lr=3e-3)

    print("phase 1: mesh 4x2 (8 chips)")
    tr = Trainer(cfg, Dist(mesh=make_mesh(data=4, model=2)), opt, tc).init(0)
    l1 = tr.train(20)
    print(f"  loss {l1[0]:.3f} -> {l1[-1]:.3f} at step {tr.step}")

    print("phase 2: 4 chips leave -> resume on 2x2 (elastic reshard)")
    tr.resume(Dist(mesh=make_mesh(data=2, model=2)))
    l2 = tr.train(40)
    print(f"  loss {l2[0]:.3f} -> {l2[-1]:.3f} at step {tr.step}")
    assert l2[0] < l1[0] + 0.2, "training continued, not restarted"

    print("phase 3: coordinator crash -> cold restore from checkpoint")
    tr2 = Trainer(cfg, Dist(mesh=make_mesh(data=2, model=2)), opt,
                  tc).init(seed=99)     # fresh (different) init...
    tr2._restore_latest()               # ...replaced by checkpoint state
    print(f"  restored at step {tr2.step}")
    assert tr2.step == 40
    l3 = tr2.train(60)
    print(f"  loss {l3[0]:.3f} -> {l3[-1]:.3f} at step {tr2.step}")
    shutil.rmtree(ckpt, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
