"""Quickstart: the paper's four-step user flow, end to end, in-process.

  1. prepare a model (manifest.yml)
  2. upload it (POST /v1/models)
  3. start + monitor a training job (POST /v1/trainings, stream logs)
  4. download the trained model

Runs a real 2-learner PSGD job on the simulated cluster in ~30s on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import io
import json
import sys
import tempfile
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.service.rest import DLaaSServer  # noqa: E402

MANIFEST = """\
name: quickstart-model
version: "1.0"
description: tiny classifier trained data-parallel over 2 learners
learners: 2
gpus: 1
memory: 1024MiB
steps: 40
lr: 0.25
solver: psgd
data_stores:
  - id: objectstore
    type: softlayer_objectstore
    training_data:
      container: my_training_data
framework:
  name: repro-mlp
  d_in: 16
  n_classes: 4
"""


def req(url, method="GET", body=None):
    data = json.dumps(body).encode() if body is not None else None
    r = urllib.request.Request(url, data=data, method=method)
    r.add_header("Authorization", "Bearer quickstart-user")
    if data:
        r.add_header("Content-Type", "application/json")
    with urllib.request.urlopen(r) as resp:
        raw = resp.read()
    try:
        return json.loads(raw)
    except json.JSONDecodeError:
        return raw


def main():
    wd = tempfile.mkdtemp(prefix="dlaas_quickstart_")
    with DLaaSServer(wd) as srv:
        print(f"DLaaS at {srv.url}")
        # (2) upload the model
        mid = req(f"{srv.url}/v1/models", "POST",
                  {"manifest": MANIFEST})["model_id"]
        print(f"deployed model {mid}")
        # (3) start training
        tid = req(f"{srv.url}/v1/trainings", "POST",
                  {"model_id": mid})["training_id"]
        print(f"training {tid} started; streaming logs:")
        with urllib.request.urlopen(
                f"{srv.url}/v1/trainings/{tid}/logs/stream") as s:
            for line in s:
                txt = line.decode().strip()
                if txt:
                    print("  " + txt)
        status = req(f"{srv.url}/v1/trainings/{tid}")
        print(f"status: {status['status']}  "
              f"steps={status['steps_done']}  "
              f"last_loss={status['last_loss']:.4f}")
        # progress indicators (paper §Understanding Training Progress)
        m = srv.core.metrics
        print(f"better than random: {m.better_than_random(tid, 4)}")
        print(f"plateaued: {m.plateaued(tid)}")
        print(f"checkpoints: {[e['step'] for e in m.checkpoints(tid)]}")
        print(f"comm overhead: {m.comm_overhead(tid):.1%}")
        # (4) download the trained model
        blob = urllib.request.urlopen(
            f"{srv.url}/v1/trainings/{tid}/model").read()
        w = np.load(io.BytesIO(blob))
        print(f"downloaded trained model: {w.size} params "
              f"({len(blob)} bytes)")
        assert status["status"] == "COMPLETED"
    print("OK")


if __name__ == "__main__":
    main()
