"""The colloquium exercise (paper §DLaaS Usage Study): users sweep
hyperparameters through the API to push accuracy as high as possible.

Submits a family of jobs with different learning rates / step budgets /
learner counts, monitors them concurrently, and reports the leaderboard —
the 71% -> 77% workflow on our synthetic classification task.

  PYTHONPATH=src python examples/hyperparam_sweep.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.service.core import DLaaSCore, default_cluster  # noqa: E402

MANIFEST = """\
name: sweep-base
learners: 1
gpus: 1
steps: 12
lr: 0.02
framework:
  name: repro-mlp
  d_in: 24
  n_classes: 6
"""


def main():
    wd = tempfile.mkdtemp(prefix="dlaas_sweep_")
    core = DLaaSCore(wd, cluster=default_cluster(8, 4))
    try:
        mid = core.deploy_model(MANIFEST, user="sweeper")["model_id"]
        grid = []
        for lr in (0.02, 0.1, 0.3):
            for steps in (12, 40):
                for learners in (1, 2):
                    grid.append({"lr": lr, "steps": steps,
                                 "learners": learners})
        jobs = []
        for hp in grid:
            tid = core.create_training(mid, overrides=hp,
                                       user="sweeper")["training_id"]
            jobs.append((tid, hp))
        print(f"submitted {len(jobs)} tuning jobs")
        board = []
        for tid, hp in jobs:
            st = core.wait_for(tid, timeout=180)
            acc = core.metrics.series(tid, "accuracy").values
            board.append((acc[-1] if acc else 0.0, hp, tid, st))
        board.sort(key=lambda r: r[0], reverse=True)
        print(f"{'acc':>6}  {'lr':>5} {'steps':>5} {'learners':>8}  job")
        for acc, hp, tid, st in board:
            print(f"{acc:6.3f}  {hp['lr']:5.2f} {hp['steps']:5d} "
                  f"{hp['learners']:8d}  {tid} [{st}]")
        base = min(a for a, *_ in board)
        best = board[0][0]
        print(f"\ntuning improved accuracy {base:.1%} -> {best:.1%} "
              f"(paper: 71% -> 77%)")
        assert best > base
    finally:
        core.close()
    print("OK")


if __name__ == "__main__":
    main()
