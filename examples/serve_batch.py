"""End-to-end serving driver: batched prefill + continuous-batching decode.

Loads a smoke-scale LM (any --arch), prefills a batch of prompts, then
decodes with a continuous-batching loop: finished sequences are retired
and queued requests join mid-flight by prefilling into the freed cache
slot — the serving pattern a production deployment of this stack uses,
exercised on CPU.

  PYTHONPATH=src python examples/serve_batch.py --arch stablelm-1.6b \
      --requests 6 --batch 3 --max-new 12
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import reduce_for_smoke  # noqa: E402
from repro.configs.registry import get_arch  # noqa: E402
from repro.distributed.sharding import Dist  # noqa: E402
from repro.models import make_model  # noqa: E402

OPTS = {"remat": "none", "xent_chunk": 32, "q_chunk": 32, "k_chunk": 32}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=64)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_arch(args.arch))
    if cfg.family in ("encdec", "vlm"):
        print(f"note: {args.arch} uses a stub frontend; serving the text "
              f"backbone only")
    model = make_model(cfg, Dist(), OPTS)
    params = model.init(jax.random.PRNGKey(0))
    B, P, CAP = args.batch, args.prompt_len, args.capacity
    rng = np.random.RandomState(0)

    # request queue
    queue = [rng.randint(0, cfg.vocab_size, size=P).astype(np.int32)
             for _ in range(args.requests)]
    eos = 0

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode, donate_argnums=(1,))

    def pad_cache(cache):
        out = dict(cache)
        for k in ("k", "v"):
            if k in out:
                pads = [(0, 0)] * out[k].ndim
                pads[2] = (0, CAP - out[k].shape[2])
                out[k] = jnp.pad(out[k], pads)
        return out

    # initial batch
    active = [queue.pop(0) for _ in range(min(B, len(queue)))]
    toks = jnp.asarray(np.stack(active))
    logits, cache = prefill(params, {"tokens": toks})
    cache = pad_cache(cache)
    outputs = {i: [] for i in range(len(active))}
    slot_req = list(range(len(active)))
    next_req = len(active)
    done = 0
    new_counts = [0] * B
    t0 = time.time()
    steps = 0

    cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    while done < args.requests:
        logits, cache = decode(params, cache, {"tokens": cur})
        steps += 1
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        cur_np = np.asarray(cur[:, 0])
        for s in range(len(slot_req)):
            r = slot_req[s]
            if r is None:
                continue
            outputs[r].append(int(cur_np[s]))
            new_counts[s] += 1
            if new_counts[s] >= args.max_new or int(cur_np[s]) == eos:
                print(f"req {r}: finished with {len(outputs[r])} tokens: "
                      f"{outputs[r][:8]}{'...' if len(outputs[r]) > 8 else ''}")
                done += 1
                slot_req[s] = None
                if queue:
                    # continuous batching: prefill the newcomer alone and
                    # splice its cache into the freed slot
                    prompt = queue.pop(0)
                    lg1, c1 = prefill(
                        params, {"tokens": jnp.asarray(prompt)[None]})
                    c1 = pad_cache(c1)
                    cache = splice(cache, c1, s)
                    slot_req[s] = next_req
                    outputs[next_req] = []
                    new_counts[s] = 0
                    nxt = nxt.at[s].set(
                        jnp.argmax(lg1[0, -1]).astype(jnp.int32))
                    next_req += 1
        cur = nxt[:, None]
    dt = time.time() - t0
    tps = steps * B / max(dt, 1e-9)
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({steps} decode steps, {tps:.1f} tok/s batched)")


def splice(cache, one, slot):
    out = dict(cache)
    for k in ("k", "v"):
        if k in out:
            out[k] = out[k].at[:, slot:slot + 1].set(one[k])
    if "ssm" in out:
        ax = 1 if out["ssm"].ndim == 5 else 2
        idx = (slice(None),) * ax + (slice(slot, slot + 1),)
        out["ssm"] = out["ssm"].at[idx].set(one["ssm"])
        axc = 1 if out["conv"].ndim == 4 else 2
        idxc = (slice(None),) * axc + (slice(slot, slot + 1),)
        out["conv"] = out["conv"].at[idxc].set(one["conv"])
    # NOTE: per-slot positions are tracked host-side; the shared scalar
    # pos is the max — valid because decode_attention masks by length.
    return out


if __name__ == "__main__":
    main()
