"""End-to-end serving driver: the train→deploy→predict loop through the
managed inference subsystem (src/repro/serving/).

Trains a tiny model through the control plane, deploys it as an
inference endpoint (an LCM job with a continuous-batching engine),
streams concurrent predict requests at it — finished sequences retire
and queued requests join mid-flight into freed KV-cache slots — then
prints the endpoint stats and drains it.

  PYTHONPATH=src python examples/serve_batch.py --arch stablelm-1.6b \
      --requests 8 --capacity 3 --max-new 8
"""
import argparse
import sys
import tempfile
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.service.core import DLaaSCore  # noqa: E402

MANIFEST = """name: serve-batch-src
learners: 1
gpus: 1
steps: {steps}
batch_docs: 2
checkpoint_every: 100
data:
  n_docs: 32
  seq_len: 16
framework:
  name: repro-lm
  arch: {arch}
"""


def wait_state(core, eid, want, timeout=300.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        st = core.endpoint_status(eid)
        if st["state"] == want:
            return st
        if st["state"] == "FAILED":
            raise SystemExit(f"endpoint {eid} FAILED "
                             f"(job {st['job_state']})")
        time.sleep(0.05)
    raise SystemExit(f"endpoint {eid} never reached {want} "
                     f"within {timeout:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=3)
    args = ap.parse_args()

    core = DLaaSCore(tempfile.mkdtemp(prefix="serve_batch_"),
                     tick_interval=0.005)
    try:
        # 1) train through the platform (weights land in the results
        #    store — the same object the endpoint will load)
        print(f"== training {args.arch} ({args.train_steps} steps) ==")
        mid = core.deploy_model(MANIFEST.format(
            arch=args.arch, steps=args.train_steps))["model_id"]
        tid = core.create_training(mid)["training_id"]
        st = core.wait_for(tid, timeout=300)
        print(f"training {tid}: {st}")
        if st != "COMPLETED":
            raise SystemExit(f"training failed: {st}")

        # 2) deploy: the endpoint is an LCM job (queued, placed,
        #    metered); DEPLOYING covers weight download + jit build
        out = core.deploy_endpoint(
            from_training=tid, capacity=args.capacity,
            max_new=args.max_new, max_queue=max(16, args.requests))
        eid = out["endpoint_id"]
        print(f"== deployed {eid} from {tid} ==")
        wait_state(core, eid, "READY")
        print("endpoint READY")

        # 3) stream concurrent predicts: more requests than slots, so
        #    late requests join mid-flight as earlier ones retire
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, 100, size=args.prompt_len)
                   for _ in range(args.requests)]
        results = [None] * args.requests
        t0 = time.time()

        def client(i):
            results[i] = core.predict(eid, prompts[i],
                                      max_new=args.max_new)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(args.requests)]
        [t.start() for t in threads]
        [t.join() for t in threads]
        wall = time.time() - t0
        for i, r in enumerate(results):
            toks = r["tokens"]
            print(f"req {i}: {len(toks)} tokens in {r['latency_s']}s: "
                  f"{toks[:8]}{'...' if len(toks) > 8 else ''}")

        # 4) stats + drain
        stats = core.endpoint_status(eid)["stats"]
        print(f"== served {stats['completed_total']} requests in "
              f"{wall:.2f}s ({stats['completed_total'] / wall:.1f} req/s, "
              f"{stats['tokens_out_total']} tokens) ==")
        print(f"   occupancy={stats['mean_batch_occupancy']} over "
              f"{stats['decode_steps']} decode steps; "
              f"p50={stats['p50_latency_s']}s "
              f"p99={stats['p99_latency_s']}s; "
              f"rejected={stats['rejected_total']}")
        core.stop_endpoint(eid)
        wait_state(core, eid, "STOPPED", timeout=60.0)
        print(f"endpoint drained and STOPPED; final stats snapshot "
              f"kept, KV buffers released")
    finally:
        core.close()


if __name__ == "__main__":
    main()
